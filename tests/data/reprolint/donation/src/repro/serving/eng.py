"""Fixture: use-after-donation (donation-discipline must fire twice —
a straight-line read of a donated buffer, and a loop that never rebinds)."""
import jax


class Engine:
    def __init__(self, fn):
        self._step = jax.jit(fn, donate_argnums=(0,))

    def run_bad(self, state, x):
        out = self._step(state, x)
        norm = state.sum()  # LINT: donation-discipline
        return out, norm

    def run_ok(self, state, x):
        state, out = self._step(state, x)
        return state.sum() + out

    def loop_bad(self, state, x):
        for _ in range(3):
            out = self._step(state, x)  # LINT: donation-discipline (wrap)
        return out

    def loop_ok(self, state, x):
        for _ in range(3):
            state, x = self._step(state, x)
        return state
