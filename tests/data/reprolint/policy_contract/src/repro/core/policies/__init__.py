"""Fixture package __init__: imports good and twice, but NOT orphan."""
from repro.core.policies import good  # noqa: F401
from repro.core.policies import twice  # noqa: F401
