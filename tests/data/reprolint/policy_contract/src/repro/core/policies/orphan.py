"""Fixture: registers a policy but is never imported from the package
__init__ — its @register never runs (policy-contract must fire)."""
from repro.core.policies.base import register


@register("orphan")
class Orphan:
    def init_state(self, batch):
        return {}
