"""Fixture registry stub (base.py is exempt from the one-policy rule)."""


def register(name):
    def deco(cls):
        cls.name = name
        return cls
    return deco
