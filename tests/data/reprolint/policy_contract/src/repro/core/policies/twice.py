"""Fixture: registers TWO policies in one module (policy-contract must
fire — one module, one policy)."""
from repro.core.policies.base import register


@register("twice-a")
class TwiceA:
    def init_state(self, batch):
        return {}


@register("twice-b")
class TwiceB:  # LINT: policy-contract
    def init_state(self, batch):
        return {}
