"""Fixture: a well-formed policy module — exactly one registration,
imported from the package __init__ (policy-contract must stay silent)."""
from repro.core.policies.base import register


@register("good")
class Good:
    def init_state(self, batch):
        return {}
