"""Per-sample batched cache gating: a static sample must keep skipping while
its moving batchmate recomputes, batched results must match per-sample
unbatched runs, and the fused Pallas gate kernel must match its reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.configs.base import FastCacheConfig
from repro.core import CachedDiT, summarize_stats, statcache
from repro.diffusion import sample
from repro.kernels import ops, ref
from repro.models import build_model
from tests.conftest import f32_cfg


def _setup(key, fc=None, policy="fastcache"):
    cfg = f32_cfg(get_reduced("dit-b2"))
    model = build_model(cfg)
    params = model.init(key)
    runner = CachedDiT(model, fc or FastCacheConfig(), policy=policy)
    return cfg, model, params, runner


def _drive_half_static(runner, params, key, cfg, idxs, steps=6):
    """Drive samples `idxs`: sample id 0 feeds constant latents, sample id 1
    doubles in amplitude every step (outruns the sliding-window tracker)."""
    img, ch = cfg.dit.image_size, cfg.dit.in_channels
    x0 = jax.random.normal(key, (2, img, img, ch))
    ids = jnp.array(idxs)
    state = runner.init_state(len(idxs))
    step = jax.jit(runner.step)
    labels = jnp.array([1, 2])[ids]
    outs = []
    for t in range(steps):
        scale = jnp.where(ids == 1, 2.0 ** t, 1.0)
        x = x0[ids] * scale[:, None, None, None]
        eps, state = step(params, state, x, jnp.full((len(idxs),), 25),
                          labels)
        outs.append(eps)
    return outs, state


def test_static_sample_skips_while_moving_recomputes(key):
    cfg, model, params, runner = _setup(key)
    _, state = _drive_half_static(runner, params, key, cfg, [0, 1])
    s = summarize_stats(state)["per_sample"]
    static_skip, moving_skip = s["blocks_skipped"]
    assert static_skip > moving_skip, s
    assert static_skip > 0.0, s
    assert moving_skip == 0.0, s
    # per-sample compute counters mirror the skips
    assert s["blocks_computed"][0] < s["blocks_computed"][1], s


def test_batched_matches_unbatched(key):
    """Running {static, moving} as one batch must reproduce each sample's
    solo run bit-for-bit stats and fp32-tolerance outputs."""
    cfg, model, params, runner = _setup(key)
    outs_b, st_b = _drive_half_static(runner, params, key, cfg, [0, 1])
    outs_0, st_0 = _drive_half_static(runner, params, key, cfg, [0])
    outs_1, st_1 = _drive_half_static(runner, params, key, cfg, [1])
    for t, (eb, e0, e1) in enumerate(zip(outs_b, outs_0, outs_1)):
        np.testing.assert_allclose(eb[0], e0[0], rtol=1e-5, atol=1e-5,
                                   err_msg=f"static sample step {t}")
        np.testing.assert_allclose(eb[1], e1[0], rtol=1e-5, atol=1e-5,
                                   err_msg=f"moving sample step {t}")
    sb = summarize_stats(st_b)["per_sample"]
    s0 = summarize_stats(st_0)["per_sample"]
    s1 = summarize_stats(st_1)["per_sample"]
    assert sb["blocks_skipped"][0] == s0["blocks_skipped"][0]
    assert sb["blocks_skipped"][1] == s1["blocks_skipped"][0]


@pytest.mark.parametrize("policy", ["teacache", "fbcache"])
def test_step_level_policies_gate_per_sample(key, policy):
    cfg, model, params, runner = _setup(key, policy=policy)
    _, state = _drive_half_static(runner, params, key, cfg, [0, 1])
    s = summarize_stats(state)["per_sample"]
    assert s["steps_reused"][0] > s["steps_reused"][1], (policy, s)


def test_global_gate_mode_couples_batch(key):
    """gate_mode='global' (the pre-refactor baseline) must give identical
    skip counts for every sample — the moving one drags the static one."""
    fc = FastCacheConfig(gate_mode="global")
    cfg, model, params, runner = _setup(key, fc=fc)
    _, state = _drive_half_static(runner, params, key, cfg, [0, 1])
    s = summarize_stats(state)["per_sample"]
    assert s["blocks_skipped"][0] == s["blocks_skipped"][1], s


def test_fused_gate_path_matches_reference_path(key):
    """CachedDiT with use_fused_gate=True (Pallas interpret on CPU) must
    reproduce the default JAX gating path."""
    cfg, model, params, runner = _setup(key)
    _, _, _, r_fused = _setup(key, fc=FastCacheConfig(use_fused_gate=True))
    outs_a, st_a = _drive_half_static(runner, params, key, cfg, [0, 1],
                                      steps=4)
    outs_b, st_b = _drive_half_static(r_fused, params, key, cfg, [0, 1],
                                      steps=4)
    for ea, eb in zip(outs_a, outs_b):
        np.testing.assert_allclose(ea, eb, rtol=1e-5, atol=1e-5)
    assert (summarize_stats(st_a)["per_sample"]["blocks_skipped"]
            == summarize_stats(st_b)["per_sample"]["blocks_skipped"])


def test_sampler_heterogeneous_batch(key):
    """Full sampling with per-sample labels and timestep offsets: shapes,
    finiteness, and per-sample stats present."""
    cfg, model, params, runner = _setup(key)
    x, state = sample(runner, params, key, batch=2,
                      labels=jnp.array([3, 7]),
                      t_offsets=jnp.array([0, 5]), num_steps=6,
                      guidance_scale=4.0)
    assert x.shape[0] == 2
    assert not bool(jnp.isnan(x).any())
    s = summarize_stats(state)
    assert len(s["per_sample"]["blocks_skipped"]) == 4  # 2B with CFG


def test_decode_reset_slot_rearms_one_slot(key):
    from repro.core import CachedDecoder
    cfg = f32_cfg(get_reduced("qwen3-0.6b"))
    model = build_model(cfg)
    dec = CachedDecoder(model, FastCacheConfig())
    st = dec.init_state(2)
    st["have_cache"] = jnp.ones((2,), bool)
    st["gate"] = statcache.GateState(
        sigma2=jnp.full((cfg.num_layers, 2), 0.5),
        initialized=jnp.ones((cfg.num_layers, 2), bool))
    st2 = dec.reset_slot(st, 1)
    assert bool(st2["have_cache"][0]) and not bool(st2["have_cache"][1])
    assert bool(st2["gate"].initialized[:, 0].all())
    assert not bool(st2["gate"].initialized[:, 1].any())
    np.testing.assert_allclose(st2["gate"].sigma2[:, 0], 0.5)
    np.testing.assert_allclose(st2["gate"].sigma2[:, 1], 1.0)


def test_decode_sigma_not_seeded_from_bootstrap(key):
    """The variance tracker must only observe deltas against a REAL previous
    hidden: the first decode step after init/reset compares against zeroed
    prev_hidden, and seeding sigma2 from ||h - 0||^2 would lock the gate
    into skipping every block forever."""
    from repro.core import CachedDecoder
    cfg = f32_cfg(get_reduced("qwen3-0.6b"))
    model = build_model(cfg)
    params = model.init(key)
    toks = jax.random.randint(key, (2, 8), 0, cfg.vocab_size)
    dec = CachedDecoder(model, FastCacheConfig())
    st = dec.init_state(2)
    logits, cache = model.prefill(params, {"tokens": toks}, window=32)
    nxt = jnp.argmax(logits, -1).astype(jnp.int32)
    logits, cache, st = dec.decode_step(params, nxt, cache, st)
    # bootstrap step (prev_hidden was zeros): nothing observed
    assert not bool(st["gate"].initialized.any())
    np.testing.assert_allclose(st["gate"].sigma2, 1.0)
    nxt = jnp.argmax(logits, -1).astype(jnp.int32)
    logits, cache, st = dec.decode_step(params, nxt, cache, st)
    # second step observed a real token-to-token delta
    assert bool(st["gate"].initialized.all())
    assert bool((st["gate"].sigma2 != 1.0).any())
    # sigma must be the token-delta scale, not the raw hidden magnitude
    h = st["prev_hidden"][1]                 # block-0 input, (B, D)
    raw_scale = float(jnp.mean(jnp.sum(h.astype(jnp.float32) ** 2, -1))
                      / h.shape[-1])
    assert float(st["gate"].sigma2.max()) < raw_scale, (
        float(st["gate"].sigma2.max()), raw_scale)


@pytest.mark.serving
def test_serving_admission_preserves_batchmate_cache(key):
    """Admitting a new request into a freed slot must reset only that slot's
    gate state; the resident request keeps decoding with its cache."""
    from repro.serving import Request, ServingEngine
    cfg = f32_cfg(get_reduced("qwen3-0.6b"))
    model = build_model(cfg)
    params = model.init(key)
    eng = ServingEngine(model, params, max_batch=2, window=64,
                        fastcache=FastCacheConfig())
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 5)
                    .astype(np.int32), max_new_tokens=4 + 3 * i)
            for i in range(3)]
    done = eng.run(reqs)
    assert len(done) == 3
    assert all(len(r.generated) == r.max_new_tokens for r in done)
    stats = eng.cache_stats()
    assert len(stats["per_slot_blocks_skipped"]) == 2
    assert stats["block_cache_ratio"] >= 0.0
