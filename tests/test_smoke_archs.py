"""REQUIRED smoke tests: every assigned architecture instantiates a reduced
variant (<=2-4 layers, d_model<=512, <=4 experts) and runs one forward/train
step on CPU, asserting output shapes and no NaNs."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED_ARCHS, get_reduced
from repro.models import build_model
from tests.conftest import f32_cfg

B, S = 2, 32


def make_batch(cfg, key, seq=S):
    if cfg.family == "audio":
        return {
            "features": jax.random.normal(key, (B, seq, cfg.frontend_dim)),
            "targets": jax.random.randint(key, (B, seq), 0, cfg.vocab_size),
            "mask_indices": jnp.ones((B, seq), bool),
        }
    if cfg.family == "dit":
        img, ch = cfg.dit.image_size, cfg.dit.in_channels
        return {
            "latents": jax.random.normal(key, (B, img, img, ch)),
            "t": jnp.array([3, 17]),
            "labels": jnp.array([1, 2]),
            "noise": jax.random.normal(key, (B, img, img, ch)),
        }
    batch = {"tokens": jax.random.randint(key, (B, seq), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        vm = jnp.zeros((B, seq), bool).at[:, 1:1 + min(cfg.vision_tokens,
                                                       seq - 2)].set(True)
        batch["vision_embeds"] = jax.random.normal(
            key, (B, cfg.vision_tokens, cfg.d_model))
        batch["vision_mask"] = vm
    return batch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_reduced_forward_and_train_step(arch, key):
    cfg = f32_cfg(get_reduced(arch))
    assert cfg.d_model <= 512
    assert cfg.num_layers <= 4
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    model = build_model(cfg)
    params = model.init(key)
    batch = make_batch(cfg, key)

    if cfg.family != "dit":
        hidden, aux = model.apply(params, batch)
        assert hidden.shape == (B, S, cfg.d_model)
        assert not bool(jnp.isnan(hidden).any())

    # one real train step (loss + grads + update)
    from repro.training import cosine_schedule, make_optimizer, make_train_step
    opt = make_optimizer(cfg.optimizer)
    step = jax.jit(make_train_step(model, opt, cosine_schedule(1e-3, 1, 10)))
    new_params, _, metrics = step(params, opt.init(params), batch)
    assert not bool(jnp.isnan(metrics["loss"])), metrics
    # params actually moved
    moved = any(
        not bool(jnp.allclose(a, b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params)))
    assert moved


@pytest.mark.parametrize("arch", [a for a in ASSIGNED_ARCHS
                                  if not get_reduced(a).is_encoder])
def test_reduced_decode_step(arch, key):
    cfg = f32_cfg(get_reduced(arch))
    model = build_model(cfg)
    params = model.init(key)
    toks = jax.random.randint(key, (B, 8), 0, cfg.vocab_size)
    logits, cache = model.prefill(params, {"tokens": toks}, window=16)
    assert logits.shape == (B, cfg.vocab_size)
    logits, cache = model.decode_step(params, toks[:, -1], cache)
    assert logits.shape == (B, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    assert int(cache["step"][0]) == 9
