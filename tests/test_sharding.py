"""Sharding rules: divisibility fallback, axis-conflict handling, per-shape
rule tables, optimizer-state sharding trees."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import (ShardingCtx, make_rules, spec_for,
                                        param_shardings, use_sharding)
from repro.models.params import ParamDef


@pytest.fixture(scope="module")
def ctx():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    return ShardingCtx(mesh, make_rules("train"))


def test_spec_basic(ctx):
    assert spec_for((64, 32), ("embed", "ffn"), ctx) == P("data", "model")


def test_divisibility_fallback(ctx):
    # 1-device axes divide everything; build a fake larger mesh via rules on
    # a mesh with extent 1 is trivial — exercise the arithmetic directly
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    big = ShardingCtx(mesh, make_rules("train"))
    assert spec_for((504,), ("vocab",), big) in (P("model"), P(None))


def test_axis_conflict_drops_second_use():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = make_rules("train")
    rules["a"] = ("model",)
    rules["b"] = ("model",)
    ctx = ShardingCtx(mesh, rules)
    spec = spec_for((8, 8), ("a", "b"), ctx)
    assert spec[1] is None  # model already consumed by dim 0


def test_long_context_rules_move_data_axis():
    r = make_rules("decode", long_context=True)
    assert "data" in r["act_kv_seq"]
    assert r["act_batch"] == ("pod",)


def test_decode_rules_shard_kv_over_model():
    r = make_rules("decode")
    assert r["act_kv_seq"] == ("model",)


def test_param_shardings_tree(ctx):
    defs = {"w": ParamDef((8, 4), ("embed", "ffn")),
            "nested": {"b": ParamDef((4,), ("ffn",))}}
    sh = param_shardings(defs, ctx)
    assert sh["w"].spec == P("data", "model")
    assert sh["nested"]["b"].spec == P("model")


def test_constrain_noop_outside_ctx():
    from repro.distributed.sharding import constrain
    x = jnp.ones((4, 4))
    assert constrain(x, "act_batch", None) is x


def test_constrain_applies_in_ctx():
    from repro.distributed.sharding import constrain
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with use_sharding(mesh, make_rules("train")):
        y = constrain(jnp.ones((4, 4)), "act_batch", "act_embed")
        assert y.shape == (4, 4)


def test_optimizer_shardings_match_structure():
    from repro.launch.specs import optimizer_shardings
    from repro.training.optimizer import Adafactor, AdamW
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ctx = ShardingCtx(mesh, make_rules("train"))
    defs = {"w": ParamDef((8, 4), ("embed", "ffn")),
            "b": ParamDef((4,), ("ffn",))}
    import jax as _jax
    from repro.models.params import abstract_params
    params = abstract_params(defs, "float32")
    for opt in (AdamW(), Adafactor()):
        sh = optimizer_shardings(opt, defs, ctx)
        sds = _jax.eval_shape(opt.init, params)
        # structures must line up leaf-for-leaf
        _jax.tree.map(lambda a, b: None, sds, sh)
