"""Continuous-batching diffusion serving: mid-flight admission must be
invisible — an admitted request reproduces its solo run bitwise *under its
own sampling plan* (per-request DDIM step budget + guidance scale),
resident requests keep their cache decisions, and per-slot gate/cache
state is fully reset on admission and on free.  Plus scheduler/queue
semantics (FIFO no-overtake, SJF ordering, deterministic tie-breaks) and
the engine's active-slot-only stats convention."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.configs.base import FastCacheConfig
from repro.core import CachedDiT, POLICIES, summarize_stats
from repro.diffusion import sample
from repro.models import build_model
from repro.serving import (DiffusionRequest, DiffusionServingEngine,
                           RequestQueue, SamplingPlan, poisson_trace,
                           summarize_by_steps)
from tests.conftest import assert_solo_replay_parity, f32_cfg

pytestmark = pytest.mark.serving

STEPS = 5


@pytest.fixture(scope="module")
def dit():
    cfg = f32_cfg(get_reduced("dit-b2"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _engine(model, params, policy, *, slots=2, guidance=4.0,
            max_steps=None):
    runner = CachedDiT(model, FastCacheConfig(), policy=policy)
    return DiffusionServingEngine(runner, params, max_slots=slots,
                                  num_steps=STEPS, guidance_scale=guidance,
                                  max_steps=max_steps)


def _staggered_trace():
    """Request 1 joins while request 0 is mid-flight; request 2 queues until
    a slot frees (admitted mid-flight next to a warm resident)."""
    return [DiffusionRequest(rid=0, label=1, seed=10, arrival_step=0),
            DiffusionRequest(rid=1, label=2, seed=11, arrival_step=2),
            DiffusionRequest(rid=2, label=3, seed=12, arrival_step=3)]


def _mixed_plan_trace():
    """Heterogeneous plans admitted mid-flight: a 7-step guided request
    next to a 3-step unguided one, plus a 5-step mid-guidance request that
    queues until a slot frees — one batch, three different schedules."""
    return [DiffusionRequest(rid=0, label=1, seed=10, arrival_step=0,
                             num_steps=7, guidance_scale=4.0),
            DiffusionRequest(rid=1, label=2, seed=11, arrival_step=2,
                             num_steps=3, guidance_scale=1.0),
            DiffusionRequest(rid=2, label=3, seed=12, arrival_step=3,
                             num_steps=5, guidance_scale=2.0)]




# ---------------------------------------------------------------------------
# Tentpole: mid-flight admission parity, every cache policy
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", POLICIES)
def test_midflight_admission_parity(dit, policy):
    """A request admitted at engine step k produces bitwise (float32) the
    same latents as running it alone from step 0, for every policy."""
    cfg, model, params = dit
    eng = _engine(model, params, policy)
    done = eng.run(_staggered_trace())
    assert len(done) == 3
    # requests without explicit plans resolve to the engine defaults
    assert all(r.num_steps == STEPS and r.guidance_scale == 4.0
               for r in done)
    assert_solo_replay_parity(eng, model, params, policy, done)
    assert all(r.latency_steps >= STEPS for r in done)


@pytest.mark.parametrize("policy", POLICIES)
def test_mixed_plan_batch_parity(dit, policy):
    """Tentpole: one batch mixes per-request step budgets AND guidance
    scales (7-step g=4, 3-step g=1, 5-step g=2; the last admitted
    mid-flight next to slots running different plans) — every finished
    request must be bitwise-equal to its solo replay under its own plan,
    for every cache policy."""
    cfg, model, params = dit
    eng = _engine(model, params, policy, max_steps=7)
    done = eng.run(_mixed_plan_trace())
    assert len(done) == 3
    # each request finishes after ITS plan's budget, not the engine default
    assert {r.rid: r.finish_step - r.admit_step for r in done} == \
        {0: 7, 1: 3, 2: 5}
    assert_solo_replay_parity(eng, model, params, policy, done)
    # request-scoped cache counters were harvested per completion
    for r in done:
        assert r.cache is not None
        assert r.cache["blocks_computed"] > 0


# ---------------------------------------------------------------------------
# Token compression composes with serving: admission parity with merge on
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def dit_real(dit):
    """Same reduced model with the adaLN-zero modulation and output head
    un-zeroed (as trained weights would be) — with plain init eps == 0 and
    merge-on parity would be vacuously bitwise."""
    cfg, model, params = dit
    params = dict(params)
    params["blocks"] = dict(params["blocks"])
    k = jax.random.PRNGKey(7)
    params["blocks"]["ada_w"] = 0.05 * jax.random.normal(
        k, params["blocks"]["ada_w"].shape)
    params["blocks"]["ada_b"] = 0.2 * jax.random.normal(
        jax.random.fold_in(k, 1), params["blocks"]["ada_b"].shape)
    params["final_w"] = (jax.random.normal(jax.random.fold_in(k, 2),
                                           params["final_w"].shape)
                         / cfg.d_model ** 0.5)
    return cfg, model, params


MERGE_FC = FastCacheConfig(merge_enabled=True, merge_ratio=0.5,
                           merge_window=8)


@pytest.mark.parametrize("policy", ("nocache", "fastcache", "teacache"))
def test_merge_midflight_admission_parity(dit_real, policy):
    """With the token-compression stage on (r=0.5), a request admitted
    mid-flight next to warm residents still reproduces its solo merge-on
    replay bitwise — the reducer's per-slot saliency state resets with the
    slot like any policy state."""
    cfg, model, params = dit_real
    runner = CachedDiT(model, MERGE_FC, policy=policy)
    assert runner.reducer is not None
    eng = DiffusionServingEngine(runner, params, max_slots=2,
                                 num_steps=STEPS, guidance_scale=4.0)
    done = eng.run(_staggered_trace())
    assert len(done) == 3
    assert_solo_replay_parity(eng, model, params, policy, done, fc=MERGE_FC)


def test_merge_mixed_plan_batch_parity(dit_real):
    """Merge stage + heterogeneous per-request plans admitted mid-flight:
    still bitwise-equal to the per-plan solo replay.  All plans keep
    guidance > 1 so solo replays stay on the CFG-doubled path the engine
    runs — with real (non-zero-eps) weights a g=1.0 solo replay takes the
    undoubled batch shape, whose XLA:CPU gemms differ in the last bits."""
    cfg, model, params = dit_real
    trace = [DiffusionRequest(rid=0, label=1, seed=10, arrival_step=0,
                              num_steps=7, guidance_scale=4.0),
             DiffusionRequest(rid=1, label=2, seed=11, arrival_step=2,
                              num_steps=3, guidance_scale=3.0),
             DiffusionRequest(rid=2, label=3, seed=12, arrival_step=3,
                              num_steps=5, guidance_scale=2.0)]
    runner = CachedDiT(model, MERGE_FC, policy="fastcache")
    eng = DiffusionServingEngine(runner, params, max_slots=2,
                                 num_steps=STEPS, guidance_scale=4.0,
                                 max_steps=7)
    done = eng.run(trace)
    assert len(done) == 3
    assert {r.rid: r.finish_step - r.admit_step for r in done} == \
        {0: 7, 1: 3, 2: 5}
    assert_solo_replay_parity(eng, model, params, "fastcache", done,
                              fc=MERGE_FC)


def test_merge_engine_counts_tokens(dit_real):
    """The engine's metrics plane reports the realized merge ratio: total
    kept/merged tokens and the per-slot kept/(kept+merged) accumulator."""
    from repro.obs import MetricsCollector
    from repro.obs import metrics as obs_metrics
    cfg, model, params = dit_real
    runner = CachedDiT(model, MERGE_FC, policy="fastcache")
    coll = MetricsCollector()
    eng = DiffusionServingEngine(runner, params, max_slots=2,
                                 num_steps=STEPS, guidance_scale=4.0,
                                 collector=coll)
    eng.run(_staggered_trace())
    h = eng.harvest_metrics()
    kept = h["counters"][obs_metrics.TOKENS_KEPT]
    merged = h["counters"][obs_metrics.TOKENS_MERGED]
    # r=0.5: exactly half the grid survives on every active row-step
    assert kept == merged > 0
    ratio = h["per_slot"][obs_metrics.SLOT_MERGE_RATIO]
    steps = h["per_slot"][obs_metrics.SLOT_ACTIVE_STEPS]
    # counters only see ACTIVE rows: slot-steps x CFG pair x kept grid
    assert kept == float(np.sum(np.asarray(steps))) * 2 \
        * runner.reducer.reduced_tokens
    np.testing.assert_allclose(np.asarray(ratio),
                               0.5 * np.asarray(steps), atol=1e-5)
    # merge-off engines carry no token metrics at all (pytree unchanged)
    off = DiffusionServingEngine(
        CachedDiT(model, FastCacheConfig(), policy="fastcache"), params,
        max_slots=2, num_steps=STEPS, collector=MetricsCollector())
    assert obs_metrics.TOKENS_KEPT not in off.metrics["counters"]


def test_plan_exceeding_table_width_is_rejected(dit):
    cfg, model, params = dit
    eng = _engine(model, params, "nocache")        # max_steps == STEPS
    with pytest.raises(ValueError, match="max_steps"):
        eng.add_request(DiffusionRequest(rid=0, label=1, seed=1,
                                         num_steps=STEPS + 1))
    with pytest.raises(ValueError):
        SamplingPlan(0)


def test_no_cfg_engine_matches_solo(dit):
    """guidance=1.0 engine: CFG rows are still materialized, but the
    per-sample blend selects eps_cond outright, so every request stays
    bitwise-equal to a solo run on the static no-CFG sample() path."""
    cfg, model, params = dit
    eng = _engine(model, params, "fastcache", guidance=1.0)
    done = eng.run(_staggered_trace())
    for r in done:
        solo = CachedDiT(model, FastCacheConfig(), policy="fastcache")
        x, _ = sample(solo, params, jax.random.PRNGKey(0), batch=1,
                      labels=jnp.array([r.label]), num_steps=STEPS,
                      guidance_scale=1.0,
                      x_init=np.asarray(eng.request_noise(r))[None])
        np.testing.assert_array_equal(np.asarray(x[0]), r.latents)


# ---------------------------------------------------------------------------
# Satellite: static no-CFG fast path (cfg_rows=False)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", ("fastcache", "fora"))
def test_no_cfg_fast_path_single_row_slots(dit, policy):
    """cfg_rows=False: single-row slots (state batch S, no uncond half —
    the model batch halves for homogeneous unguided traffic) while every
    request stays bitwise-equal both to its solo replay and to the default
    CFG-rows engine at guidance 1.0, mid-flight admission included."""
    cfg, model, params = dit
    runner = CachedDiT(model, FastCacheConfig(), policy=policy)
    fast = DiffusionServingEngine(runner, params, max_slots=2,
                                  num_steps=STEPS, guidance_scale=1.0,
                                  cfg_rows=False)
    assert fast.rows_per_slot == 1
    assert fast.state["stats"]["blocks_computed"].shape == (2,)
    assert list(np.asarray(fast._slot_rows(1))) == [1]
    done_fast = fast.run(_staggered_trace())
    assert len(done_fast) == 3
    assert_solo_replay_parity(fast, model, params, policy, done_fast)
    full = _engine(model, params, policy, guidance=1.0)
    done_full = full.run(_staggered_trace())
    a = {r.rid: r.latents for r in done_fast}
    for r in done_full:
        np.testing.assert_array_equal(a[r.rid], r.latents,
                                      err_msg=f"rid={r.rid}")


def test_no_cfg_fast_path_rejects_guided_traffic(dit):
    """The fast path is guidance==1.0-only: a guided default at
    construction or a guided request at admission must raise (there are no
    uncond rows to serve it from)."""
    cfg, model, params = dit
    runner = CachedDiT(model, FastCacheConfig())
    with pytest.raises(ValueError, match="cfg_rows"):
        DiffusionServingEngine(runner, params, max_slots=2,
                               num_steps=STEPS, cfg_rows=False)
    eng = DiffusionServingEngine(runner, params, max_slots=2,
                                 num_steps=STEPS, guidance_scale=1.0,
                                 cfg_rows=False)
    with pytest.raises(ValueError, match="no-CFG"):
        eng.add_request(DiffusionRequest(rid=0, label=1, seed=1,
                                         guidance_scale=4.0))
    # explicit guidance 1.0 is fine
    assert eng.add_request(DiffusionRequest(rid=1, label=1, seed=1,
                                            guidance_scale=1.0))


# ---------------------------------------------------------------------------
# Satellite: mixed-have_cache per-sample warm-up at the runner level
# ---------------------------------------------------------------------------

def test_batched_with_straggler_matches_solo(dit):
    key = jax.random.PRNGKey(3)
    """Runner-level parity: sample 0 runs 6 steps; sample 1 is reset
    (straggler admission) after step 3 and restarts.  Both must match their
    solo runs bitwise — the mixed warm-up step must not force the resident
    sample off its gated path, nor corrupt its trackers."""
    cfg, model, params = dit
    img, ch = cfg.dit.image_size, cfg.dit.in_channels
    xa = jax.random.normal(key, (6, img, img, ch))          # sample 0 inputs
    xb = jax.random.normal(jax.random.fold_in(key, 1), (6, img, img, ch))
    runner = CachedDiT(model, FastCacheConfig())
    step = jax.jit(runner.step)
    t = jnp.full((2,), 25)
    labels = jnp.array([1, 2])

    state = runner.init_state(2)
    outs = []
    for i in range(6):
        if i == 3:
            state = runner.reset_slot(state, 1)
        # sample 1 replays xb from its own step 0 after the reset
        x = jnp.stack([xa[i], xb[i - 3 if i >= 3 else i]])
        eps, state = step(params, state, x, t, labels)
        outs.append(np.asarray(eps))

    def solo(xs, label, n):
        st = runner.init_state(1)
        res = []
        for i in range(n):
            eps, st = step(params, st, xs[i][None], jnp.full((1,), 25),
                           jnp.full((1,), label))
            res.append(np.asarray(eps[0]))
        return res, st

    sa, st_a = solo(xa, 1, 6)
    sb, st_b = solo(xb, 2, 3)
    for i in range(6):
        np.testing.assert_array_equal(outs[i][0], sa[i], err_msg=f"A@{i}")
    for i in range(3):
        np.testing.assert_array_equal(outs[3 + i][1], sb[i],
                                      err_msg=f"B@{i}")
    # stats parity for the resident sample (bitwise counters)
    s = summarize_stats(state)["per_sample"]
    assert s["blocks_skipped"][0] == \
        summarize_stats(st_a)["per_sample"]["blocks_skipped"][0]
    assert s["blocks_skipped"][1] >= \
        summarize_stats(st_b)["per_sample"]["blocks_skipped"][0]


# ---------------------------------------------------------------------------
# Satellite: per-slot state reset on admission and on free
# ---------------------------------------------------------------------------

def _assert_slot_reset(eng, s):
    """The slot's rows of every fastcache state buffer are re-armed (the
    plugin state is minimal: only fastcache's own buffers exist)."""
    rows = np.asarray(eng._slot_rows(s))
    st = eng.state
    assert set(st) == {"prev_tokens_in", "prev_hidden", "gate",
                       "have_cache", "stats"}
    assert not np.asarray(st["have_cache"])[rows].any()
    assert not np.asarray(st["gate"].initialized)[:, rows].any()
    np.testing.assert_array_equal(np.asarray(st["gate"].sigma2)[:, rows], 1.0)
    assert not np.asarray(st["prev_hidden"])[:, rows].any()
    assert not np.asarray(st["prev_tokens_in"])[rows].any()


def test_slot_state_reset_on_admission_and_free(dit):
    cfg, model, params = dit
    eng = _engine(model, params, "fastcache")
    # dirty every slot: run one request to completion in slot 0 while
    # slot 1 stays idle (its padding rows still evolve state)
    [r0] = eng.run([DiffusionRequest(rid=0, label=1, seed=5)])
    assert r0.done
    # freed on finish: slot 0 rows are fully reset
    _assert_slot_reset(eng, 0)
    # admission resets the target slot's rows before the first step
    assert eng.add_request(DiffusionRequest(rid=1, label=2, seed=6))
    _assert_slot_reset(eng, 0)
    eng.step()
    assert np.asarray(eng.state["have_cache"])[np.asarray(
        eng._slot_rows(0))].all()


# ---------------------------------------------------------------------------
# Satellite: backend auto-selection of the fused gate
# ---------------------------------------------------------------------------

def test_auto_fused_gate_backend_default(dit):
    cfg, model, params = dit
    assert FastCacheConfig().use_fused_gate is None
    auto = CachedDiT(model, FastCacheConfig())
    assert auto.use_fused == (jax.default_backend() == "tpu")
    on = CachedDiT(model, FastCacheConfig(use_fused_gate=True))
    off = CachedDiT(model, FastCacheConfig(use_fused_gate=False))
    assert on.use_fused is True and off.use_fused is False


# ---------------------------------------------------------------------------
# Scheduler / queue semantics
# ---------------------------------------------------------------------------

def test_poisson_trace_is_sorted_and_deterministic():
    a = poisson_trace(20, 0.5, seed=7, num_classes=10)
    b = poisson_trace(20, 0.5, seed=7, num_classes=10)
    arr = [r.arrival_step for r in a]
    assert arr == sorted(arr)
    assert arr == [r.arrival_step for r in b]
    assert [r.seed for r in a] == [r.seed for r in b]
    # higher rate => denser arrivals
    dense = poisson_trace(20, 5.0, seed=7, num_classes=10)
    assert dense[-1].arrival_step <= a[-1].arrival_step


def test_poisson_trace_draws_plans_from_mix():
    a = poisson_trace(40, 0.5, seed=7, num_classes=10,
                      steps_mix=(20, 50), guidance_mix=(1.0, 4.0))
    assert {r.num_steps for r in a} == {20, 50}
    assert {r.guidance_scale for r in a} == {1.0, 4.0}
    b = poisson_trace(40, 0.5, seed=7, num_classes=10,
                      steps_mix=(20, 50), guidance_mix=(1.0, 4.0))
    assert [(r.num_steps, r.guidance_scale) for r in a] == \
        [(r.num_steps, r.guidance_scale) for r in b]
    # no mix -> plan fields stay unset (engine defaults apply)
    c = poisson_trace(4, 0.5, seed=7, num_classes=10)
    assert all(r.num_steps is None and r.guidance_scale is None for r in c)


def test_request_queue_gates_on_arrival():
    q = RequestQueue([DiffusionRequest(rid=1, label=0, arrival_step=4),
                      DiffusionRequest(rid=0, label=0, arrival_step=1)])
    assert q.pop_arrived(0) is None
    assert q.pop_arrived(2).rid == 0
    assert q.peek_arrived(2) is None          # rid 1 not arrived yet
    assert q.pop_arrived(4).rid == 1
    assert not q


def test_fifo_no_overtake_even_with_late_push():
    """FIFO hands out strictly by (arrival_step, rid) — a request pushed
    late but with an earlier arrival still pops first, and nothing
    overtakes an earlier arrival that is already eligible."""
    q = RequestQueue([DiffusionRequest(rid=2, label=0, arrival_step=5),
                      DiffusionRequest(rid=1, label=0, arrival_step=3)])
    assert q.peek_arrived(6).rid == 1
    # late push of an EARLIER arrival (e.g. a retried request)
    q.push(DiffusionRequest(rid=0, label=0, arrival_step=1))
    assert [q.pop_arrived(6).rid for _ in range(3)] == [0, 1, 2]
    assert q.pop_arrived(6) is None


def test_sjf_orders_by_step_budget_under_equal_arrivals():
    """SJF pops the smallest step budget among ARRIVED requests; arrival
    gating still applies (a short job that hasn't arrived can't jump)."""
    q = RequestQueue([
        DiffusionRequest(rid=0, label=0, arrival_step=0, num_steps=50),
        DiffusionRequest(rid=1, label=0, arrival_step=0, num_steps=20),
        DiffusionRequest(rid=2, label=0, arrival_step=4, num_steps=5),
    ], policy="sjf")
    assert q.pop_arrived(0).rid == 1          # shortest arrived job
    assert q.pop_arrived(0).rid == 0          # rid2 not arrived yet
    assert q.pop_arrived(0) is None
    assert q.pop_arrived(4).rid == 2


def test_sjf_tie_breaks_are_deterministic():
    """Equal budgets fall back to (arrival_step, rid); requests without an
    explicit plan sort as longest."""
    q = RequestQueue([
        DiffusionRequest(rid=3, label=0, arrival_step=0),  # no plan: longest
        DiffusionRequest(rid=2, label=0, arrival_step=0, num_steps=20),
        DiffusionRequest(rid=1, label=0, arrival_step=0, num_steps=20),
        DiffusionRequest(rid=0, label=0, arrival_step=1, num_steps=20),
    ], policy="sjf")
    assert [q.pop_arrived(2).rid for _ in range(4)] == [1, 2, 0, 3]


def test_unknown_sched_policy_rejected():
    with pytest.raises(ValueError, match="scheduling policy"):
        RequestQueue([], policy="lifo")


@pytest.mark.parametrize("policy", ("fifo", "sjf"))
def test_queue_tolerates_duplicate_keys(policy):
    """Two requests sharing (arrival_step, rid) — e.g. a retry pushed while
    the original is still queued — must not crash heap ordering (requests
    are not comparable; the internal seq counter breaks the tie)."""
    a = DiffusionRequest(rid=1, label=0, arrival_step=0, num_steps=20)
    b = DiffusionRequest(rid=1, label=0, arrival_step=0, num_steps=20)
    q = RequestQueue([a], policy=policy)
    q.push(b)
    assert {q.pop_arrived(0), q.pop_arrived(0)} == {a, b}
    assert q.pop_arrived(0) is None


def test_summarize_by_steps_tolerates_empty_and_unfinished_groups():
    """Regression: truncated traces used to trip ``np.percentile`` on an
    empty array.  Empty input -> {}; a group whose every request was cut
    off unfinished reports its count with -1.0 sentinel percentiles; and
    requests with an unresolved plan (num_steps=None — admission refused
    them before the engine ever resolved it) land in a ``"rejected"``
    group so the trace total is conserved, instead of materializing a
    'None' group or vanishing."""
    assert summarize_by_steps([]) == {}

    cut = DiffusionRequest(rid=0, label=0, arrival_step=0, num_steps=8)
    ok = DiffusionRequest(rid=1, label=0, arrival_step=2, num_steps=4)
    ok.finish_step = 10
    unresolved = DiffusionRequest(rid=2, label=0, arrival_step=0)
    out = summarize_by_steps([cut, ok, unresolved])
    assert set(out) == {"4", "8", "rejected"}
    assert out["rejected"]["requests"] == 1
    assert out["rejected"]["finished"] == 0
    assert out["8"] == {"requests": 1, "finished": 0,
                        "latency_steps_p50": -1.0,
                        "latency_steps_p95": -1.0}
    assert out["4"]["finished"] == 1
    assert out["4"]["latency_steps_p50"] == 8.0
    # cache aggregation only engages when every request carries counters
    ok.cache = {"blocks_skipped": 3.0, "blocks_computed": 1.0,
                "steps_reused": 2.0}
    out = summarize_by_steps([ok])
    assert out["4"]["cache_ratio"] == 0.75
    assert out["4"]["steps_reused"] == 2.0


def test_sampling_plan_rows_match_solo_schedule():
    """A plan's padded ts/ts_prev rows agree with diffusion.schedule's DDIM
    timestep math for its own budget; padding is never a valid step."""
    from repro.diffusion import schedule as sch
    plan = SamplingPlan(5, 2.0)
    ts, prev = plan.rows(8, num_train_steps=1000)
    ref = np.asarray(sch.ddim_timesteps(1000, 5))
    np.testing.assert_array_equal(ts[:5], ref[:5])
    np.testing.assert_array_equal(prev[:4], ref[1:5])
    assert prev[4] == ref[5] if len(ref) > 5 else prev[4] == -1
    np.testing.assert_array_equal(ts[5:], 0)
    np.testing.assert_array_equal(prev[5:], -1)
    with pytest.raises(ValueError, match="max_steps"):
        SamplingPlan(9).rows(8)


def test_engine_run_respects_sjf_policy(dit):
    """End-to-end: with one slot and a long resident, SJF admits the short
    queued job before the long one; FIFO preserves arrival order."""
    cfg, model, params = dit

    def trace():
        return [DiffusionRequest(rid=0, label=1, seed=30, arrival_step=0,
                                 num_steps=4),
                DiffusionRequest(rid=1, label=2, seed=31, arrival_step=1,
                                 num_steps=5),
                DiffusionRequest(rid=2, label=3, seed=32, arrival_step=2,
                                 num_steps=2)]

    order = {}
    for sched in ("fifo", "sjf"):
        eng = _engine(model, params, "nocache", slots=1, max_steps=5)
        done = eng.run(trace(), sched_policy=sched)
        order[sched] = [r.rid for r in sorted(done,
                                              key=lambda r: r.admit_step)]
    assert order["fifo"] == [0, 1, 2]
    assert order["sjf"] == [0, 2, 1]


# ---------------------------------------------------------------------------
# Engine stats + lockstep-vs-continuous latency ordering
# ---------------------------------------------------------------------------

def test_engine_stats_active_only(dit):
    cfg, model, params = dit
    eng = _engine(model, params, "fora")
    done = eng.run(_staggered_trace())
    stats = eng.cache_stats()
    assert stats["policy"] == "fora"
    assert stats["blocks_computed"] > 0
    assert stats["steps_reused"] > 0          # fora reuses 2 of every 3
    assert 0.0 < stats["block_cache_ratio"] < 1.0
    assert len(stats["per_slot_blocks_skipped"]) == 4   # 2 slots x CFG pair
    # idle padding decisions are excluded from the headline counters
    per_slot_total = sum(stats["per_slot_blocks_skipped"]) + \
        sum(stats["per_slot_blocks_computed"])
    assert stats["blocks_computed"] + stats["blocks_skipped"] \
        <= per_slot_total


def test_continuous_beats_lockstep_p95(dit):
    """r0 occupies a slot; r1/r2 arrive mid-flight.  Continuous admission
    uses the free slot immediately; lockstep waits for the wave to drain."""
    cfg, model, params = dit

    def trace():
        return [DiffusionRequest(rid=0, label=1, seed=20, arrival_step=0),
                DiffusionRequest(rid=1, label=2, seed=21, arrival_step=2),
                DiffusionRequest(rid=2, label=3, seed=22, arrival_step=2),
                DiffusionRequest(rid=3, label=4, seed=23, arrival_step=2)]

    lats = {}
    for lockstep in (False, True):
        eng = _engine(model, params, "fastcache")
        done = eng.run(trace(), lockstep=lockstep)
        lats[lockstep] = sorted(r.latency_steps for r in done)
    # every request is no later under continuous admission, and the tail
    # (the queued requests) is strictly earlier
    assert all(c <= l for c, l in zip(lats[False], lats[True]))
    assert lats[False][-1] < lats[True][-1]
