"""End-to-end training driver: train a DiT on the synthetic latent pipeline,
checkpoint, then sample from it with FastCache.

Scales from CPU smoke (default) to the paper's DiT-B/2 (126M params):

    PYTHONPATH=src python examples/train_dit.py --steps 120          # CPU
    PYTHONPATH=src python examples/train_dit.py --size b2 --steps 300
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp

import repro.checkpoint as ckpt
from repro.configs import get_reduced
from repro.configs.base import DiTConfig, FastCacheConfig
from repro.configs.dit import DIT_B2, DIT_S2
from repro.core import CachedDiT, summarize_stats
from repro.data import latent_stream
from repro.diffusion import sample
from repro.models import build_model
from repro.training import AdamW, cosine_schedule, train


def pick_config(size: str):
    if size == "smoke":
        return get_reduced("dit-b2").replace(dtype="float32")
    base = {"s2": DIT_S2, "b2": DIT_B2}[size]
    return base.replace(dtype="float32", dit=dataclasses.replace(
        base.dit, num_classes=10, image_size=16))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", default="smoke", choices=["smoke", "s2", "b2"])
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--save", default="/tmp/dit_ckpt")
    args = ap.parse_args()

    cfg = pick_config(args.size)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"[train] {cfg.name}: {n/1e6:.1f}M params, {args.steps} steps")

    it = latent_stream(args.batch, cfg.dit.image_size, cfg.dit.in_channels,
                       num_classes=cfg.dit.num_classes, seed=0)

    def log(i, m):
        print(f"[train] step {i:4d} mse={m['loss']:.4f} "
              f"({m['elapsed_s']:.1f}s)", flush=True)

    params, _, hist = train(model, params, AdamW(weight_decay=0.01),
                            cosine_schedule(args.lr, 10, args.steps), it,
                            steps=args.steps, log_every=20, callback=log)
    print(f"[train] loss {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f}")
    if args.save:
        ckpt.save(args.save, params, {"arch": cfg.name,
                                      "steps": args.steps,
                                      "final_loss": hist[-1]["loss"]})
        print(f"[train] checkpoint -> {args.save}")

    # sample from the trained model with FastCache
    runner = CachedDiT(model, FastCacheConfig(), policy="fastcache")
    x, st = sample(runner, params, jax.random.PRNGKey(7), batch=2,
                   labels=jnp.array([1, 2]), num_steps=20)
    s = summarize_stats(st)
    print(f"[sample] {x.shape} finite={bool(jnp.isfinite(x).all())} "
          f"cache_ratio={s['block_cache_ratio']:.1%}")


if __name__ == "__main__":
    main()
