"""Generate class-conditional latents with every cache policy and compare —
the runnable version of the paper's Table 1 experiment.

    PYTHONPATH=src python examples/generate_images.py --steps 20 --out /tmp/gen
"""
import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.configs.base import FastCacheConfig
from repro.core import CachedDiT, POLICIES, summarize_stats
from repro.diffusion import sample
from repro.models import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="dit-b2")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--guidance", type=float, default=4.0)
    ap.add_argument("--out", default="")
    args = ap.parse_args()

    cfg = get_reduced(args.arch).replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    labels = jnp.arange(args.batch) % cfg.dit.num_classes

    ref = None
    print(f"{'policy':10s} {'time_s':>8s} {'cache%':>7s} {'reused':>6s}"
          f" {'rel_err':>8s}")
    for policy in POLICIES:
        runner = CachedDiT(model, FastCacheConfig(), policy=policy)
        x, st = sample(runner, params, key, batch=args.batch, labels=labels,
                       num_steps=args.steps, guidance_scale=args.guidance)
        jax.block_until_ready(x)
        t0 = time.perf_counter()
        x, st = sample(runner, params, key, batch=args.batch, labels=labels,
                       num_steps=args.steps, guidance_scale=args.guidance)
        jax.block_until_ready(x)
        dt = time.perf_counter() - t0
        if policy == "nocache":
            ref = x
        s = summarize_stats(st)
        rel = float(jnp.linalg.norm(x - ref) / jnp.linalg.norm(ref))
        print(f"{policy:10s} {dt:8.3f} {s['block_cache_ratio']:7.1%}"
              f" {s['steps_reused']:6.0f} {rel:8.4f}")
        if args.out:
            os.makedirs(args.out, exist_ok=True)
            np.save(os.path.join(args.out, f"latents_{policy}.npy"),
                    np.asarray(x))
    if args.out:
        print(f"latents saved under {args.out}/")


if __name__ == "__main__":
    main()
