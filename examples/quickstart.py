"""Quickstart: FastCache vs exact sampling on a small DiT, in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.configs.base import FastCacheConfig
from repro.core import CachedDiT, summarize_stats
from repro.diffusion import sample
from repro.models import build_model

cfg = get_reduced("dit-b2").replace(dtype="float32")
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
key = jax.random.PRNGKey(42)
labels = jnp.array([3, 7])

print(f"model: {cfg.name} ({cfg.num_layers}L d={cfg.d_model})")

# --- exact (no cache) ------------------------------------------------------
runner = CachedDiT(model, FastCacheConfig(), policy="nocache")
x_ref, _ = sample(runner, params, key, batch=2, labels=labels, num_steps=20)
jax.block_until_ready(x_ref)
t0 = time.perf_counter()
x_ref, _ = sample(runner, params, key, batch=2, labels=labels, num_steps=20)
jax.block_until_ready(x_ref)
t_ref = time.perf_counter() - t0

# --- FastCache (paper defaults: tau_s=0.05, alpha=0.05, gamma=0.5) ---------
runner = CachedDiT(model, FastCacheConfig(), policy="fastcache")
x_fc, st = sample(runner, params, key, batch=2, labels=labels, num_steps=20)
jax.block_until_ready(x_fc)
t0 = time.perf_counter()
x_fc, st = sample(runner, params, key, batch=2, labels=labels, num_steps=20)
jax.block_until_ready(x_fc)
t_fc = time.perf_counter() - t0

s = summarize_stats(st)
rel = float(jnp.linalg.norm(x_fc - x_ref) / jnp.linalg.norm(x_ref))
print(f"exact    : {t_ref:.3f}s")
print(f"fastcache: {t_fc:.3f}s  (block cache ratio "
      f"{s['block_cache_ratio']:.1%}, motion fraction "
      f"{s['mean_motion_fraction']:.1%})")
print(f"relative deviation from exact sampler: {rel:.4f}")
