"""Serve image-generation requests through the continuous-batching diffusion
engine: requests arrive over time (Poisson), join the running batch
mid-flight, and each keeps its own FastCache state — the serving twin of
examples/generate_images.py.

    PYTHONPATH=src python examples/serve_images.py --steps 8 --requests 6
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_reduced
from repro.configs.base import FastCacheConfig
from repro.core import CachedDiT
from repro.models import build_model
from repro.serving import DiffusionServingEngine, poisson_trace


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="dit-b2")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--steps", type=int, default=8)
    ap.add_argument("--rate", type=float, default=0.3)
    ap.add_argument("--policy", default="fastcache")
    ap.add_argument("--guidance", type=float, default=4.0)
    ap.add_argument("--out", default="")
    args = ap.parse_args()

    cfg = get_reduced(args.arch).replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    runner = CachedDiT(model, FastCacheConfig(), policy=args.policy)
    engine = DiffusionServingEngine(runner, params, max_slots=args.slots,
                                    num_steps=args.steps,
                                    guidance_scale=args.guidance)
    trace = poisson_trace(args.requests, args.rate, seed=0,
                          num_classes=cfg.dit.num_classes)
    t0 = time.perf_counter()
    done = engine.run(trace)
    dt = time.perf_counter() - t0

    print(f"{'rid':>4s} {'label':>5s} {'arrive':>6s} {'admit':>6s}"
          f" {'finish':>6s} {'latency':>7s}")
    for r in sorted(done, key=lambda r: r.rid):
        print(f"{r.rid:4d} {r.label:5d} {r.arrival_step:6d} "
              f"{r.admit_step:6d} {r.finish_step:6d} {r.latency_steps:7d}")
    print(f"{len(done)} requests in {dt:.2f}s over {engine.clock} engine "
          f"steps; cache: {engine.cache_stats()['block_cache_ratio']:.1%} "
          f"blocks skipped (active slots)")
    if args.out:
        import os
        os.makedirs(args.out, exist_ok=True)
        for r in done:
            np.save(os.path.join(args.out, f"latents_req{r.rid}.npy"),
                    r.latents)
        print(f"latents saved under {args.out}/")


if __name__ == "__main__":
    main()
