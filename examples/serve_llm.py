"""Serve a small LLM with batched prefill/decode, with and without the
FastCache decode gate (beyond-paper application of the paper's chi^2 cache).

    PYTHONPATH=src python examples/serve_llm.py --requests 6 --new-tokens 24
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_reduced
from repro.configs.base import FastCacheConfig
from repro.models import build_model
from repro.serving import Request, ServingEngine


def run(engine, cfg, n, prompt_len, new_tokens, seed=0):
    rng = np.random.default_rng(seed)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        prompt_len).astype(np.int32),
                    max_new_tokens=new_tokens) for i in range(n)]
    t0 = time.perf_counter()
    done = engine.run(reqs)
    dt = time.perf_counter() - t0
    toks = sum(len(r.generated) for r in done)
    return done, toks, dt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = get_reduced(args.arch).replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    eng = ServingEngine(model, params, max_batch=4, window=128)
    done, toks, dt = run(eng, cfg, args.requests, args.prompt_len,
                         args.new_tokens)
    print(f"exact     : {toks} tokens in {dt:.2f}s ({toks/dt:.1f} tok/s)")

    eng_fc = ServingEngine(model, params, max_batch=4, window=128,
                           fastcache=FastCacheConfig())
    done_fc, toks_fc, dt_fc = run(eng_fc, cfg, args.requests,
                                  args.prompt_len, args.new_tokens)
    st = eng_fc.cache_stats()
    print(f"fastcache : {toks_fc} tokens in {dt_fc:.2f}s "
          f"({toks_fc/dt_fc:.1f} tok/s, block cache ratio "
          f"{st['block_cache_ratio']:.1%})")
    agree = np.mean([np.mean(np.array(a.generated) == np.array(b.generated))
                     for a, b in zip(done, done_fc)])
    print(f"greedy-token agreement vs exact: {agree:.1%}")


if __name__ == "__main__":
    main()
